"""Tests for the observability layer (``repro.obs``).

Histogram quantiles vs exact sample percentiles (the bounded-relative-
error property), span nesting + Chrome trace-event schema validity, the
disabled-tracer no-op property (NULL_SPAN identity, zero events), the
metrics registry (get-or-create, kind mismatch, snapshot/diff), and
Prometheus text-exposition parseability."""
import json
import math
import random
import re

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_compat import given, settings, strategies as st

from repro.obs import metrics as om
from repro.obs import trace as ot


# ---------------------------------------------------------------------
# histogram: log-bucketed quantiles vs exact percentiles
# ---------------------------------------------------------------------

def _exact_pct(samples, q):
    s = sorted(samples)
    return s[max(0, math.ceil(q * len(s)) - 1)]


@settings(max_examples=12, deadline=None)
@given(st.integers(0, 10_000))
def test_histogram_quantiles_track_exact_percentiles(seed):
    """The estimate must sit within a factor sqrt(growth) of the exact
    sample percentile — the histogram's designed error bound — for
    latency-like samples spanning several orders of magnitude."""
    rnd = random.Random(seed)
    h = om.Histogram("lat")
    n = rnd.randrange(5, 400)
    # lognormal-ish spread: 10us .. 10s
    samples = [10 ** rnd.uniform(-5, 1) for _ in range(n)]
    for x in samples:
        h.observe(x)
    bound = math.sqrt(h.growth) * (1 + 1e-9)
    for q in (0.5, 0.9, 0.99):
        exact = _exact_pct(samples, q)
        est = h.quantile(q)
        assert exact / bound <= est <= exact * bound, (q, exact, est)
    assert h.count == n
    assert h.min == min(samples) and h.max == max(samples)
    assert h.sum == pytest.approx(sum(samples))


def test_histogram_edge_cases():
    h = om.Histogram("h")
    assert h.quantile(0.5) == 0.0            # empty
    h.observe(0.0)                           # at/below min_value: bucket 0
    h.observe(-1.0)
    assert h.quantile(0.99) <= h.min_value
    h2 = om.Histogram("h2")
    h2.observe(3.25)                         # single sample: clamps exact
    assert h2.quantile(0.5) == pytest.approx(3.25)
    assert h2.quantile(0.99) == pytest.approx(3.25)
    with pytest.raises(ValueError):
        om.Histogram("bad", growth=1.0)


def test_histogram_memory_is_bounded_by_buckets_not_samples():
    h = om.Histogram("h")
    rnd = random.Random(3)
    for _ in range(10_000):
        h.observe(10 ** rnd.uniform(-6, 1))
    # 7 decades at ~19%/bucket: well under 150 buckets for 10k samples
    assert len(h._buckets) < 150
    assert h.count == 10_000


# ---------------------------------------------------------------------
# tracer: disabled no-op, nesting, Chrome schema
# ---------------------------------------------------------------------

def test_disabled_tracer_is_a_shared_noop():
    tr = ot.Tracer()
    assert tr.span("x") is ot.NULL_SPAN       # no allocation per call
    with tr.span("x") as sp:
        sp.set(a=1)
    tr.instant("y")
    assert tr.events == [] and tr.dropped == 0
    # module-level path: off by default in a fresh tracer swap
    with ot.use(ot.Tracer()):
        assert ot.span("x") is ot.NULL_SPAN


def test_bypass_short_circuits_even_when_enabled():
    with ot.bypass() as tr:
        tr.enable()                           # bypass ignores enabled
        assert tr.span("x") is ot.NULL_SPAN
        assert ot.span("x") is ot.NULL_SPAN
        assert tr.events == []


def test_span_nesting_and_chrome_trace_schema(tmp_path):
    # deterministic injectable clock: each read advances 1ms
    t = [0.0]

    def clock():
        t[0] += 1e-3
        return t[0]

    tr = ot.Tracer(clock=clock)
    tr.enable()
    with ot.use(tr):
        with ot.span("outer", cat="test", depth=0):
            with ot.span("inner", cat="test") as sp:
                sp.set(depth=1)
            ot.instant("marker", note="hi")
    doc = tr.chrome_trace()
    json.dumps(doc)                           # must be JSON-able
    evs = doc["traceEvents"]
    assert [e["name"] for e in evs] == ["inner", "marker", "outer"]
    by_name = {e["name"]: e for e in evs}
    for e in evs:
        assert set(e) >= {"name", "cat", "ph", "ts", "pid", "tid", "args"}
        assert e["ts"] >= 0
    assert by_name["outer"]["ph"] == "X" and by_name["marker"]["ph"] == "i"
    # time containment (what viewers nest by): inner inside outer
    outer, inner = by_name["outer"], by_name["inner"]
    assert outer["ts"] <= inner["ts"]
    assert inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"]
    assert inner["args"] == {"depth": 1}
    # export round-trip
    path = tr.export(str(tmp_path / "trace.json"))
    with open(path) as f:
        assert json.load(f)["traceEvents"] == evs


def test_tracer_drops_beyond_max_events():
    tr = ot.Tracer(max_events=3)
    tr.enable()
    for i in range(5):
        tr.instant(f"e{i}")
    assert len(tr.events) == 3 and tr.dropped == 2
    assert tr.chrome_trace()["otherData"]["dropped_events"] == 2
    tr.clear()
    assert tr.events == [] and tr.dropped == 0


# ---------------------------------------------------------------------
# metrics registry + Prometheus exposition
# ---------------------------------------------------------------------

def test_registry_get_or_create_and_kind_mismatch():
    reg = om.MetricsRegistry()
    c = reg.counter("reqs", "requests")
    assert reg.counter("reqs") is c
    c.inc()
    c.inc(4)
    g = reg.gauge("depth")
    g.set(7)
    with pytest.raises(TypeError):
        reg.gauge("reqs")
    with pytest.raises(TypeError):
        reg.histogram("depth")
    snap = reg.snapshot()
    assert snap["reqs"] == 5 and snap["depth"] == 7


def test_snapshot_diff():
    reg = om.MetricsRegistry()
    reg.counter("c").inc(10)
    h = reg.histogram("h")
    h.observe(1.0)
    s0 = reg.snapshot()
    reg.counter("c").inc(5)
    h.observe(2.0)
    h.observe(4.0)
    d = om.diff_snapshots(reg.snapshot(), s0)
    assert d["c"] == 5
    assert d["h"]["count"] == 2 and d["h"]["sum"] == pytest.approx(6.0)


# every exposition line must be a comment or `name[{quantile="q"}] value`
_PROM_LINE = re.compile(
    r'^(# (HELP|TYPE) [a-zA-Z_:][a-zA-Z0-9_:]* .+'
    r'|[a-zA-Z_:][a-zA-Z0-9_:]*(\{quantile="[0-9.]+"\})? -?[0-9][0-9a-z.+-]*)$')


def test_prometheus_exposition_parses():
    reg = om.MetricsRegistry()
    reg.counter("rpq_submitted_total", "total submissions").inc(3)
    reg.gauge("rpq_in_flight", "slots busy").set(2)
    h = reg.histogram("rpq_e2e_seconds", "end to end")
    for v in (0.001, 0.002, 0.004):
        h.observe(v)
    reg.counter("weird-name.with chars").inc()
    text = reg.to_prometheus()
    assert text.endswith("\n")
    for line in text.splitlines():
        assert _PROM_LINE.match(line), line
    assert "rpq_e2e_seconds_count 3" in text
    assert 'rpq_e2e_seconds{quantile="0.5"}' in text
    assert "weird_name_with_chars 1" in text   # sanitised name
