"""Shared test helpers: random regex/graph generators."""
import random

from repro.core import regex as rx


def rand_expr_ast(rnd: random.Random, depth: int, npred: int,
                  allow_inverse: bool = True):
    r = rnd.random()
    if depth <= 0 or r < 0.4:
        inv = allow_inverse and rnd.random() < 0.3
        return rx.Lit(str(rnd.randrange(npred)), inverse=inv)
    if r < 0.6:
        return rx.Cat(rand_expr_ast(rnd, depth - 1, npred, allow_inverse),
                      rand_expr_ast(rnd, depth - 1, npred, allow_inverse))
    if r < 0.75:
        return rx.Alt(rand_expr_ast(rnd, depth - 1, npred, allow_inverse),
                      rand_expr_ast(rnd, depth - 1, npred, allow_inverse))
    if r < 0.85:
        return rx.Star(rand_expr_ast(rnd, depth - 1, npred, allow_inverse))
    if r < 0.95:
        return rx.Plus(rand_expr_ast(rnd, depth - 1, npred, allow_inverse))
    return rx.Opt(rand_expr_ast(rnd, depth - 1, npred, allow_inverse))
