"""Continuous-batching slot scheduler: parity against one-shot
``eval_many`` / the oracle over random arrival interleavings (both
engines, including under interleaved updates at snapshot epochs),
admission backpressure, deadline preemption, incremental pair streaming,
the dynamic PlanBundle slot allocator, the async serving layer, and the
``benchmarks/compare.py`` perf-regression gate."""
import asyncio
import random

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_compat import given, settings, strategies as st

from repro.core.engines import PlanBundle, Query, eval_many, make_engine
from repro.core.fixtures import random_graph
from repro.core.oracle import eval_oracle
from repro.core.scheduler import (AsyncServer, Backpressure, QueryTicket,
                                  SlotScheduler)

EXPRS = ["0/1*", "(0|1)/2", "2+", "^1/0*", "0/1/2", "(0|2)*"]


def _random_query(rnd, V):
    expr = rnd.choice(EXPRS)
    shape = rnd.randrange(4)
    if shape == 0:
        return Query(expr, obj=rnd.randrange(V))
    if shape == 1:
        return Query(expr, subject=rnd.randrange(V))
    if shape == 2:
        return Query(expr, subject=rnd.randrange(V), obj=rnd.randrange(V))
    return Query(expr)            # unanchored — delegated synchronously


# ---------------------------------------------------------------------
# THE acceptance property: continuous admission/retirement returns
# exactly the one-shot eval_many answer sets, on both engines
# ---------------------------------------------------------------------

@settings(max_examples=6, deadline=None)
@given(st.integers(0, 10_000))
def test_scheduler_matches_eval_many_random_interleavings(seed):
    rnd = random.Random(seed)
    g = random_graph(12, 3, 40, seed=1 + seed % 7, pred_zipf=False)
    queries = [_random_query(rnd, g.num_nodes)
               for _ in range(rnd.randrange(4, 14))]
    for kind in ("ring", "dense"):
        eng = make_engine(g, kind)
        want = eval_many(make_engine(g, kind), queries)
        sched = SlotScheduler(eng, max_slots=rnd.randrange(1, 5))
        tickets: list = []
        i = 0
        # random arrival interleaving: submissions and ticks in any order
        while i < len(queries) or sched.pending():
            if i < len(queries) and rnd.random() < 0.5:
                tickets.append(sched.submit(queries[i]))
                i += 1
            else:
                sched.step()
        for q, t, w in zip(queries, tickets, want):
            assert t.result() == w, (kind, q)
            # streaming soundness: for unlimited queries the drained
            # pairs union to exactly the final answer
            if q.limit is None:
                assert t._emitted == w, (kind, q)


@settings(max_examples=6, deadline=None)
@given(st.integers(0, 10_000))
def test_scheduler_snapshot_isolation_under_updates(seed):
    """Interleave submit / step / submit_update arbitrarily: every
    ticket's answer must equal the oracle on the *effective graph at the
    ticket's admission epoch* — in-flight queries are never torn by a
    concurrent write (copy-on-write overlay clone)."""
    rnd = random.Random(seed)
    g = random_graph(11, 3, 35, seed=2 + seed % 5, pred_zipf=False)
    V, P = g.num_nodes, g.num_preds
    for kind in ("ring", "dense"):
        eng = make_engine(g, kind)
        sched = SlotScheduler(eng, max_slots=2)
        snapshots = {0: eng.effective_graph()}
        issued = []            # (ticket, query)
        for _ in range(rnd.randrange(10, 30)):
            op = rnd.random()
            if op < 0.45:
                issued.append((sched.submit(_random_query(rnd, V)), None))
                issued[-1] = (issued[-1][0], issued[-1][0].query)
            elif op < 0.65:
                adds = [(rnd.randrange(V), rnd.randrange(P),
                         rnd.randrange(V))
                        for _ in range(rnd.randrange(1, 3))]
                rems = [(rnd.randrange(V), rnd.randrange(P),
                         rnd.randrange(V))]
                ep = sched.submit_update(add=adds, remove=rems)
                snapshots[ep] = eng.effective_graph()
            else:
                sched.step()
        sched.drain()
        for ticket, q in issued:
            want = eval_oracle(snapshots[ticket.epoch], q.expr,
                               q.subject, q.obj)
            assert ticket.result() == want, (kind, q, ticket.epoch)


# ---------------------------------------------------------------------
# admission control, deadlines, streaming, limits
# ---------------------------------------------------------------------

def test_backpressure_rejects_at_max_queue():
    g = random_graph(10, 2, 20, seed=2, pred_zipf=False)
    sched = SlotScheduler(make_engine(g, "ring"), max_slots=1, max_queue=2)
    sched.submit(Query("0/1*", obj=1))
    sched.submit(Query("0/1*", obj=2))
    with pytest.raises(Backpressure):
        sched.submit(Query("0/1*", obj=3))
    assert sched.rejected == 1
    sched.drain()
    # queue drained -> admission opens again
    t = sched.submit(Query("0/1*", obj=3))
    sched.drain()
    assert t.result() == eval_oracle(g, "0/1*", None, 3)


def test_deadline_preempts_in_flight_slot_and_spares_stragglers():
    g = random_graph(12, 3, 40, seed=6, pred_zipf=False)
    clk = [0.0]
    for kind in ("ring", "dense"):
        sched = SlotScheduler(make_engine(g, kind), max_slots=1,
                              clock=lambda: clk[0])
        clk[0] = 0.0
        slow = sched.submit(Query("(0|1|2)*", obj=5), deadline_s=1.0)
        fast = sched.submit(Query("0/1*", obj=3))
        sched.step()                  # admits `slow` into the only slot
        assert slow.state == "running"
        clk[0] = 2.0                  # past the deadline mid-flight
        sched.drain()
        with pytest.raises(TimeoutError):
            slow.result()
        assert sched.preempted == 1 and sched.in_flight == 0
        # the preemption freed the slot for the query queued behind it
        assert fast.result() == eval_oracle(g, "0/1*", None, 3), kind


def test_deadline_expires_queued_ticket_before_admission():
    g = random_graph(10, 2, 20, seed=2, pred_zipf=False)
    clk = [0.0]
    sched = SlotScheduler(make_engine(g, "ring"), clock=lambda: clk[0])
    t = sched.submit(Query("0/1*", obj=1), deadline_s=0.5)
    clk[0] = 1.0
    sched.drain()
    with pytest.raises(TimeoutError):
        t.result()


def test_limit_queries_do_not_stream_and_truncate_sorted():
    g = random_graph(12, 3, 45, seed=19, pred_zipf=False)
    full = sorted(eval_oracle(g, "0/1*", None, 3))
    assert len(full) >= 2, "fixture must have enough results to truncate"
    for kind in ("ring", "dense"):
        sched = SlotScheduler(make_engine(g, kind))
        t = sched.submit(Query("0/1*", obj=3, limit=2))
        sched.drain()
        # a limited answer is the sorted prefix, so partial pairs cannot
        # stream (the first k discovered are not the k smallest)
        assert t.new_pairs() == []
        assert t.result() == set(full[:2]), kind


def test_result_cache_hit_completes_without_occupying_a_slot():
    g = random_graph(10, 2, 20, seed=2, pred_zipf=False)
    sched = SlotScheduler(make_engine(g, "ring"))
    a = sched.submit(Query("0/1*", obj=1))
    sched.drain()
    b = sched.submit(Query("0/1*", obj=1))
    sched.step()
    assert b.done and b.result() == a.result()
    assert sched.cache_hits == 1 and sched.admitted == 1


# ---------------------------------------------------------------------
# dynamic PlanBundle slots
# ---------------------------------------------------------------------

def test_plan_bundle_dynamic_slots_reuse_freed_blocks():
    class _G:                      # minimal stand-in with a state count
        def __init__(self, m):
            self.m = m

    class _P:
        def __init__(self, m):
            self.g = _G(m)

    b = PlanBundle.empty()
    p1, p2, p3 = _P(2), _P(6), _P(2)
    off1 = b.add_slot(p1, p1.g.m + 1)        # bucket 4
    off2 = b.add_slot(p2, p2.g.m + 1)        # bucket 8
    assert (off1, off2) == (0, 4)
    assert b.padded_total >= b.S_total
    b.free_slot(p1)
    # freed bucket-4 block is reused before growing the bundle
    assert b.add_slot(p3, p3.g.m + 1) == off1
    assert len(b.live_plans()) == 2
    # refcounting: the same plan object admitted twice frees once
    off2b = b.add_slot(p2, p2.g.m + 1)
    assert off2b == off2
    b.free_slot(p2)
    assert any(p is p2 for p, _ in b.live_plans())
    b.free_slot(p2)
    assert not any(p is p2 for p, _ in b.live_plans())


def test_plan_bundle_static_build_rejects_slot_ops():
    class _G:
        def __init__(self, m):
            self.m = m

    class _P:
        def __init__(self, m):
            self.g = _G(m)

    b = PlanBundle.build([_P(2)], [3])
    with pytest.raises(ValueError):
        b.add_slot(_P(2), 3)


# ---------------------------------------------------------------------
# async serving layer
# ---------------------------------------------------------------------

def test_async_server_streams_pairs_and_settles():
    g = random_graph(12, 3, 40, seed=6, pred_zipf=False)
    eng = make_engine(g, "dense")

    async def main():
        async with AsyncServer(SlotScheduler(eng, max_slots=2)) as server:
            t1 = await server.submit(Query("0/1*", obj=3))
            t2 = await server.submit(Query("(0|1)/2", subject=2))
            streamed = [p async for p in t1]
            return streamed, await t1.result(), await t2.result()

    streamed, r1, r2 = asyncio.run(main())
    assert set(streamed) == r1 == eval_oracle(g, "0/1*", None, 3)
    assert r2 == eval_oracle(g, "(0|1)/2", 2, None)


def test_async_server_interleaves_updates():
    g = random_graph(11, 3, 35, seed=23, pred_zipf=False)
    eng = make_engine(g, "ring")

    async def main():
        sched = SlotScheduler(eng, max_slots=2)
        async with AsyncServer(sched) as server:
            before = eng.effective_graph()
            t1 = await server.submit(Query("0/1*", obj=3))
            server.submit_update(add=[(0, 1, 3), (2, 0, 1)])
            after = eng.effective_graph()
            t2 = await server.submit(Query("0/1*", obj=3))
            return before, after, await t1.result(), await t2.result(), t1, t2

    before, after, r1, r2, t1, t2 = asyncio.run(main())
    assert r1 == eval_oracle(before if t1.ticket.epoch == 0 else after,
                             "0/1*", None, 3)
    assert t2.ticket.epoch == 1
    assert r2 == eval_oracle(after, "0/1*", None, 3)


# ---------------------------------------------------------------------
# observability: latency attribution, spans, metrics endpoint
# ---------------------------------------------------------------------

@settings(max_examples=6, deadline=None)
@given(st.integers(0, 10_000))
def test_latency_attribution_sums_under_random_interleavings(seed):
    """For every settled ticket, queue_wait_s + service_s equals the
    end-to-end latency (finished_at - submitted_at) under the injectable
    clock — across random submit/tick interleavings, cache hits,
    delegated queries, and both engines."""
    rnd = random.Random(seed)
    g = random_graph(12, 3, 40, seed=1 + seed % 7, pred_zipf=False)
    clk = [0.0]
    for kind in ("ring", "dense"):
        sched = SlotScheduler(make_engine(g, kind),
                              max_slots=rnd.randrange(1, 4),
                              clock=lambda: clk[0])
        queries = [_random_query(rnd, g.num_nodes)
                   for _ in range(rnd.randrange(3, 9))]
        tickets = []
        i = 0
        while i < len(queries) or sched.pending():
            clk[0] += rnd.random() * 0.01    # time passes between events
            if i < len(queries) and rnd.random() < 0.5:
                tickets.append(sched.submit(queries[i]))
                i += 1
            else:
                sched.step()
        for t in tickets:
            assert t.state == "done"
            s = t.stats
            assert s.queue_wait_s >= 0.0 and s.service_s >= 0.0
            assert s.queue_wait_s + s.service_s == pytest.approx(
                t.finished_at - t.submitted_at, rel=1e-12, abs=1e-12)
            # superstep dispatch time is a sub-interval of service
            assert s.supersteps_s <= s.service_s + 1e-12


def test_zero_slack_deadline_preempts_deterministically():
    """now == deadline preempts (the >= comparison) — both a queued
    ticket and one holding a slot — and preempted tickets record their
    queue wait in the metrics."""
    g = random_graph(12, 3, 40, seed=6, pred_zipf=False)
    clk = [0.0]
    sched = SlotScheduler(make_engine(g, "ring"), max_slots=1,
                          clock=lambda: clk[0])
    # mid-flight: admitted at 0.0, clock lands exactly on the deadline
    running = sched.submit(Query("(0|1|2)*", obj=5), deadline_s=1.0)
    sched.step()
    assert running.state == "running"
    # queued: the only slot is held, so this one waits in the queue
    queued = sched.submit(Query("0/1*", obj=3), deadline_s=1.0)
    clk[0] = 1.0
    sched.step()
    for t in (running, queued):
        assert t.state == "failed"
        with pytest.raises(TimeoutError):
            t.result()
    assert sched.preempted == 2
    assert queued.stats.queue_wait_s == pytest.approx(1.0)
    snap = sched.metrics_snapshot()
    assert snap["rpq_preempted_queue_wait_seconds"]["count"] == 2
    assert snap["rpq_preempted_queue_wait_seconds"]["max"] >= 1.0


def test_spans_cover_scheduler_and_both_engines():
    """A traced drain produces admission, superstep, and retire spans —
    plus the engine's own superstep span — for ring AND dense, and the
    result is a valid Chrome trace document."""
    import json
    from repro.obs import trace as otrace
    g = random_graph(12, 3, 40, seed=6, pred_zipf=False)
    for kind, eng_span in (("ring", "ring.superstep"),
                           ("dense", "dense.superstep")):
        tr = otrace.Tracer()
        tr.enable()
        with otrace.use(tr):
            sched = SlotScheduler(make_engine(g, kind), max_slots=2)
            sched.submit(Query("0/1*", obj=3))
            sched.submit(Query("(0|1)/2", subject=2))
            sched.drain()
        names = {e["name"] for e in tr.events}
        assert {"scheduler.tick", "scheduler.admit", "scheduler.superstep",
                "scheduler.retire", eng_span} <= names, (kind, names)
        json.dumps(tr.chrome_trace())         # schema is JSON-able
    # and with the (default-off) module tracer, the same drain records
    # nothing and allocates no spans
    sched = SlotScheduler(make_engine(g, "ring"), max_slots=2)
    from repro.obs.trace import NULL_SPAN, TRACER
    assert not TRACER.enabled
    sched.submit(Query("0/1*", obj=3))
    sched.drain()
    assert TRACER.events == []


def test_async_server_metrics_endpoint_scrapes():
    g = random_graph(10, 2, 20, seed=2, pred_zipf=False)
    eng = make_engine(g, "dense")

    async def main():
        sched = SlotScheduler(eng, max_slots=2)
        async with AsyncServer(sched, metrics_port=0) as server:
            t = await server.submit(Query("0/1*", obj=1))
            await t.result()
            host, port = server.metrics_addr
            reader, writer = await asyncio.open_connection(host, port)
            writer.write(b"GET /metrics HTTP/1.0\r\n\r\n")
            await writer.drain()
            data = await reader.read()
            writer.close()
            return data.decode()

    text = asyncio.run(main())
    head, body = text.split("\r\n\r\n", 1)
    assert "200 OK" in head
    assert "rpq_completed_total 1" in body
    assert 'rpq_e2e_seconds{quantile="0.5"}' in body


# ---------------------------------------------------------------------
# benchmarks/compare.py — the perf-regression gate
# ---------------------------------------------------------------------

def _compare_mod():
    import sys
    from pathlib import Path
    sys.path.insert(0, str(Path(__file__).resolve().parents[1]))
    from benchmarks import compare
    return compare


def test_compare_gate_fails_on_injected_slowdown(tmp_path):
    compare = _compare_mod()
    prev = {"smoke": True, "suites": {}, "rows": {
        "serving/dense/qps100/slot_p99_ms": 10.0,
        "serving/dense/qps100/p99_speedup": 4.0,
        "updates/ingest/us_per_edge": 100.0,
        "updates/query/overlay64/overlay_rows": 64.0,   # not gated
    }}
    good = {"smoke": True, "suites": {}, "rows": {
        **prev["rows"],
        "serving/dense/qps100/slot_p99_ms": 12.0,       # +20% — within 25%
        "new/only_in_current_us": 5.0,                  # no baseline: skips
    }}
    bad = {"smoke": True, "suites": {}, "rows": {
        **prev["rows"],
        "serving/dense/qps100/slot_p99_ms": 12.6,       # +26% — regression
        "serving/dense/qps100/p99_speedup": 2.9,        # -27.5% — regression
        "updates/query/overlay64/overlay_rows": 1e9,    # ignored: not gated
    }}
    import json
    pf = tmp_path / "prev.json"
    pf.write_text(json.dumps(prev))
    gf = tmp_path / "good.json"
    gf.write_text(json.dumps(good))
    bf = tmp_path / "bad.json"
    bf.write_text(json.dumps(bad))
    assert compare.main(["--current", str(gf), "--previous", str(pf)]) == 0
    assert compare.main(["--current", str(bf), "--previous", str(pf)]) == 1
    regs = compare.compare_rows(prev["rows"], bad["rows"])
    assert {k for k, *_ in regs} == {"serving/dense/qps100/slot_p99_ms",
                                     "serving/dense/qps100/p99_speedup"}


def test_compare_gate_skips_without_previous(tmp_path, capsys, monkeypatch):
    compare = _compare_mod()
    import json
    cf = tmp_path / "cur.json"
    cf.write_text(json.dumps({"smoke": True, "suites": {}, "rows": {}}))
    # missing file baseline
    assert compare.main(["--current", str(cf),
                         "--previous", str(tmp_path / "absent.json")]) == 0
    # --fetch-previous without credentials
    monkeypatch.delenv("GITHUB_TOKEN", raising=False)
    monkeypatch.delenv("GITHUB_REPOSITORY", raising=False)
    assert compare.main(["--current", str(cf), "--fetch-previous"]) == 0
    out = capsys.readouterr().out
    assert "SKIPPED" in out
